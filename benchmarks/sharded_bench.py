"""Sharded answer-GEMM scaling: 1 → 8 fake devices on one host.

Measures the online hot path  ans = D·Q (mod 2^32)  with the packed DB
row-sharded over submeshes of 1, 2, 4 and 8 fake CPU devices (queries
replicated, zero collectives — `distributed.collectives.row_shard_gemm`),
plus the bucketed batch-PIR pass spread over the same submeshes.

Fake host devices share one physical CPU, so wall-clock SPEEDUP is not the
point (XLA already multithreads the single-device GEMM); what the sweep
validates and records is that (a) per-device DB bytes fall as 1/shards —
the memory-capacity axis that lets the 8.6 GB production DB fit HBM —
while (b) total wall-clock stays flat rather than regressing, i.e. the
sharded path adds no hidden wire or resharding cost on top of the kernel.
Results are bitwise-checked against the 1-device answer in-loop.

XLA pins the host device count at first init, so the sweep runs in a child
interpreter (same pattern as tests/_mesh_harness.py); `run(fast=...)` is
what `benchmarks/run.py` calls to fill the `sharded` section of
BENCH_pirrag.json.

    PYTHONPATH=src python -m benchmarks.sharded_bench [--fast]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

_CHILD = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys, time
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import pir
from repro.distributed import collectives
from repro.kernels import ops

m, n, batch, iters = {m}, {n}, {batch}, {iters}
rng = np.random.default_rng(0)
db_host = rng.integers(0, 256, (m, n), dtype=np.uint8)
q_host = rng.integers(0, 2**32, (n, batch), dtype=np.uint32)
cfg = pir.make_config(m, n, impl="xla")

rows = []
ref = None
for n_dev in (1, 2, 4, 8):
    mesh = jax.make_mesh((n_dev,), ("chunks",),
                         devices=jax.devices()[:n_dev])
    server = pir.PIRServer(cfg, jnp.asarray(db_host), mesh=mesh)
    q = jnp.asarray(q_host)
    ans = jax.block_until_ready(server.answer(q))      # warm up + compile
    got = np.asarray(ans)
    if ref is None:
        ref = got
    else:
        np.testing.assert_array_equal(got, ref)        # bitwise across meshes
    t0 = time.perf_counter()
    for _ in range(iters):
        ans = server.answer(q)
    jax.block_until_ready(ans)
    dt = (time.perf_counter() - t0) / iters
    rows.append(dict(
        n_devices=n_dev,
        us_per_call=dt * 1e6,
        db_bytes_per_device=m * n // n_dev,
        hint_bytes_per_device=cfg.hint_bytes // n_dev,
        queries_per_s=batch / dt,
    ))

# bucketed batch-PIR pass over the same submeshes
from repro import batchpir
used = np.full(n, m, np.int64)
brows = []
bref = None
for n_dev in (1, 2, 4, 8):
    mesh = jax.make_mesh((n_dev,), ("chunks",),
                         devices=jax.devices()[:n_dev])
    bp = batchpir.build(db_host, used, cfg.params, kappa=4, seed=3,
                        impl="xla", mesh=mesh)
    key = jax.random.PRNGKey(0)
    qs, st = bp.client.query(key, [0, 1, 2])
    ans = [jax.block_until_ready(a) for a in bp.server.answer_batch(qs)]
    got = [np.asarray(a) for a in ans]
    if bref is None:
        bref = got
    else:
        for a, b in zip(got, bref):
            np.testing.assert_array_equal(a, b)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = bp.server.answer_batch(qs)
    jax.block_until_ready(out[-1])
    dt = (time.perf_counter() - t0) / iters
    brows.append(dict(n_devices=n_dev, us_per_call=dt * 1e6,
                      n_buckets=bp.partition.n_buckets,
                      stored_bytes_per_device=bp.server.stored_bytes
                      // n_dev))

base = rows[0]["us_per_call"]
ratio = max(r["us_per_call"] for r in rows) / base
checks = []
checks.append(("PASS" if ratio < 3.0 else "FAIL")
              + ": sharded answer stays within 3x of 1-device wall-clock "
              + "on shared silicon (worst %.2fx)" % ratio)
cap8 = rows[-1]["db_bytes_per_device"]
checks.append(("PASS" if cap8 * 8 == m * n else "FAIL")
              + ": per-device DB bytes scale exactly 1/shards")
print(json.dumps(dict(answer=rows, bucketed=brows, checks=checks,
                      shape=dict(m=m, n=n, batch=batch))))
"""


def run(*, fast: bool = False) -> dict:
    """Run the sweep in a child interpreter; returns the parsed section."""
    params = (dict(m=16384, n=512, batch=32, iters=5) if fast
              else dict(m=65536, n=1024, batch=64, iters=10))
    proc = subprocess.run(
        [sys.executable, "-c", _CHILD.format(**params)],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": os.path.join(os.path.dirname(__file__), "..",
                                        "src"),
             "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
             "HOME": os.environ.get("HOME", "/root"),
             "JAX_PLATFORMS": "cpu"})
    if proc.returncode != 0:
        raise RuntimeError(proc.stdout + "\n" + proc.stderr)
    return json.loads(proc.stdout.splitlines()[-1])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    res = run(fast=args.fast)
    print("name,us_per_call,derived")
    for r in res["answer"]:
        print(f"sharded_answer_d{r['n_devices']},{r['us_per_call']:.1f},"
              f"db_per_dev={r['db_bytes_per_device']};"
              f"qps={r['queries_per_s']:.0f}")
    for r in res["bucketed"]:
        print(f"sharded_bucketed_d{r['n_devices']},{r['us_per_call']:.1f},"
              f"stored_per_dev={r['stored_bytes_per_device']}")
    for c in res["checks"]:
        print("#", c)


if __name__ == "__main__":
    main()
