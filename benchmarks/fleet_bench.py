"""Fault-tolerant fleet serving benchmark: the degradation contract, timed.

Three experiments over the replica-group + fleet-serve stack:

identity        With no faults injected, `FleetServeLoop` over an R=2
                replica group replays a scripted FakeClock workload
                BIT-IDENTICALLY to a plain `PipelinedServeLoop` — the
                fleet layer is free until a fault fires.

shard loss      Calibrate the fleet loop's sustainable throughput, then
                offer open-loop Poisson traffic at 0.8× of it while one
                device of the authority rank is lost mid-run.  The group
                fails over to the replica, serves at bounded staleness,
                re-admits the returned rank by journal replay and fails
                back.  Report: SLO attainment (the headline claim:
                >= 0.9 despite the loss), served p99 (finite), failover
                detection latency (ticks and estimated seconds).

recovery        Journal-replay re-admission of a cold host across a
                K-epoch history: wall time, epochs/s, and the bit-identity
                of the recovered hint versus the never-failed source.

    PYTHONPATH=src python -m benchmarks.fleet_bench [--fast]
"""
from __future__ import annotations

import argparse
import copy
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


class FakeClock:
    """Monotone virtual clock for the identity replay (fixed step/read)."""

    def __init__(self, step: float = 1e-4):
        self.t = 0.0
        self.step = step

    def __call__(self) -> float:
        self.t += self.step
        return self.t


def _mutator_for(corp):
    from repro.update import journal as journal_lib
    n = len(corp.texts)

    def mutator(rng):
        d = int(rng.integers(n))
        return journal_lib.replace(d, f"refresh {d}".encode(),
                                   corp.embeddings[d])
    return mutator


def _identity_check(corp, live) -> dict:
    """No-fault fleet run ≡ plain pipelined run, responses and clock."""
    from repro.fleet import FleetServeLoop, ReplicaGroup
    from repro.serve import PipelinedServeLoop
    from repro.update import journal as journal_lib

    def drive(loop):
        rng = np.random.default_rng(5)
        n = len(corp.texts)
        for i in range(48):
            loop.submit(i, corp.embeddings[int(rng.integers(n))], top_k=3)
            roll = int(rng.integers(10))
            if roll < 2:
                loop.submit_mutation(journal_lib.replace(
                    i % n, f"m{i}".encode(), corp.embeddings[(i + 1) % n]))
            if roll >= 7:
                loop.tick()
        loop.drain()
        return [(r.rid, r.epoch, r.retries, r.batch_size,
                 tuple(d for d, _, _ in r.top)) for r in loop.responses]

    plain = PipelinedServeLoop(copy.deepcopy(live), max_batch=4,
                               deadline_ms=1e9, clock=FakeClock(), seed=0,
                               depth=2)
    sig_plain = drive(plain)
    group = ReplicaGroup.from_live(copy.deepcopy(live), n_replicas=2,
                                   n_shards=4)
    fleet = FleetServeLoop(group, max_batch=4, deadline_ms=1e9,
                           clock=FakeClock(), seed=0, depth=2)
    sig_fleet = drive(fleet)
    return dict(identical=sig_plain == sig_fleet,
                clock_identical=plain.clock.t == fleet.clock.t,
                n_responses=len(sig_fleet),
                failovers=group.failovers)


def _make_fleet(live, shape, *, faults=None):
    from repro.fleet import FleetServeLoop, ReplicaGroup
    group = ReplicaGroup.from_live(copy.deepcopy(live), n_replicas=2,
                                   n_shards=4,
                                   heartbeat_timeout=2, sync_lag=2,
                                   catchup_per_tick=2)
    loop = FleetServeLoop(group, max_batch=shape["max_batch"],
                          deadline_ms=shape["loop_deadline_ms"],
                          depth=2, donate=True, seed=0, faults=faults)
    return group, loop


def _calibrate(live, corp, shape, mutator) -> float:
    """Sustainable qps of the (no-fault) fleet loop, derated for commits.

    Same method as traffic_bench: closed-loop mixed-probe service rate,
    scaled down by the fraction of each second the configured mutation
    rate spends inside epoch commits (commits are serving downtime — and
    under failover they are also what the catch-up replays).
    """
    _, loop = _make_fleet(live, shape)
    rng = np.random.default_rng(0)
    n_docs = len(corp.texts)
    # warm the GEMM widths the sweep will hit before timing anything
    rid = 10_000_000
    for mp in (1, 4):
        for width in range(1, shape["max_batch"] + 1):
            for _ in range(width):
                loop.submit(rid, corp.embeddings[rid % n_docs],
                            multi_probe=mp)
                rid += 1
            loop.drain()
    loop.submit_mutation(mutator(np.random.default_rng(99)))
    loop.drain()
    t0 = time.perf_counter()
    n = shape["calibrate_n"]
    for i in range(n):
        loop.submit(i, corp.embeddings[int(rng.integers(n_docs))],
                    multi_probe=4 if i % 4 == 0 else 1)
        loop.tick()
    loop.drain()
    mixed_qps = n / (time.perf_counter() - t0)
    t0 = time.perf_counter()
    loop.submit_mutation(mutator(rng))
    loop.drain()
    commit_s = time.perf_counter() - t0
    return mixed_qps * max(0.2, 1.0 - shape["mutation_qps"] * commit_s)


def _shard_loss_point(live, corp, shape, qps: float, mutator) -> dict:
    """0.8× load with one authority device lost mid-run; SLO summary."""
    from repro.fleet import FaultPlan
    from repro.traffic import OpenLoopDriver, TrafficSpec

    expected = int(qps * shape["duration_s"])
    plan = FaultPlan.single_shard_loss(at_tick=max(4, expected // 4),
                                       device=0,
                                       down_ticks=max(8, expected // 4))
    group, loop = _make_fleet(live, shape, faults=plan.compile())
    spec = TrafficSpec(qps=qps, duration_s=shape["duration_s"],
                       n_sessions=shape["n_sessions"],
                       probe_mix=((1, 0.75), (4, 0.25)),
                       staleness_tolerance=2,
                       mutation_qps=shape["mutation_qps"],
                       max_retries=16, seed=7)
    t0 = time.perf_counter()
    res = OpenLoopDriver(loop, corp.embeddings, spec, mutator=mutator).run()
    wall = time.perf_counter() - t0
    s = res.summary(deadline_ms=shape["deadline_ms"])
    served = [r for r in res.records if r.outcome == "served"]
    lat = sorted(r.latency_ms for r in served)
    s["served_p99_ms"] = (round(lat[int(np.ceil(0.99 * len(lat))) - 1], 3)
                          if lat else 0.0)
    detect_ticks = (group.last_failover_tick - group.last_loss_tick
                    if group.failovers else -1)
    tick_s = wall / max(group.ticks, 1)
    stale = [r.staleness for r in loop.responses if r.staleness > 0]
    s.update(
        failovers=group.failovers, failbacks=group.failbacks,
        outage=group.outage,
        failover_detect_ticks=detect_ticks,
        failover_detect_ms=round(detect_ticks * tick_s * 1e3, 3),
        max_staleness=max(stale, default=0),
        stale_served=len(stale),
        readmissions=group.hosts[0].readmissions,
        failback_replay_s=(round(group.replay_reports[-1].wall_s, 4)
                           if group.replay_reports else 0.0))
    return s


def _recovery_timing(corp, live, shape) -> dict:
    """Cold host catches up K epochs by journal replay: wall + identity."""
    from repro.fleet import readmit

    src = copy.deepcopy(live)
    cold = copy.deepcopy(live)
    rng = np.random.default_rng(3)
    n = len(corp.texts)
    for e in range(shape["recovery_epochs"]):
        for _ in range(3):
            d = int(rng.integers(n))
            src.replace(d, f"e{e} {d}".encode(), corp.embeddings[d])
        src.commit()
    report = readmit(cold, src.journal)
    identical = bool(np.array_equal(np.asarray(cold.system.hint),
                                    np.asarray(src.system.hint)))
    return dict(epochs=report.epochs, mutations=report.mutations,
                wall_s=round(report.wall_s, 4),
                epochs_per_s=round(report.epochs / max(report.wall_s, 1e-9),
                                   2),
                bit_identical=identical)


def run(*, fast: bool = False) -> dict:
    from repro.data import corpus as corpus_lib
    from repro.update import LiveIndex

    if fast:
        shape = dict(n_docs=1200, n_clusters=64, emb_dim=48, max_batch=16,
                     calibrate_n=96, duration_s=2.0, n_sessions=16,
                     mutation_qps=1.0, loop_deadline_ms=10.0,
                     deadline_ms=400.0, kmeans_iters=6, recovery_epochs=12)
    else:
        shape = dict(n_docs=3000, n_clusters=192, emb_dim=48, max_batch=32,
                     calibrate_n=160, duration_s=3.0, n_sessions=32,
                     mutation_qps=1.0, loop_deadline_ms=10.0,
                     deadline_ms=400.0, kmeans_iters=8, recovery_epochs=24)
    corp = corpus_lib.make_corpus(0, shape["n_docs"],
                                  emb_dim=shape["emb_dim"],
                                  n_topics=shape["n_clusters"])
    live = LiveIndex.build(corp.texts, corp.embeddings,
                           n_clusters=shape["n_clusters"], impl="xla",
                           kmeans_iters=shape["kmeans_iters"],
                           compact_every=4)
    mutator = _mutator_for(corp)

    ident = _identity_check(corp, live)
    sustainable = _calibrate(live, corp, shape, mutator)
    loss = _shard_loss_point(live, corp, shape, 0.8 * sustainable, mutator)
    rec = _recovery_timing(corp, live, shape)

    accounted = loss["served"] + loss["shed"] + loss["failed"] \
        == loss["offered"]
    checks = [
        ("PASS" if ident["identical"] and ident["clock_identical"]
         else "FAIL")
        + ": no-fault fleet serving is bit-identical to the plain "
          "pipelined loop (%d responses, same virtual-clock trajectory)"
        % ident["n_responses"],
        ("PASS" if loss["attainment"] >= 0.9 and loss["failovers"] >= 1
         and accounted else "FAIL")
        + ": SLO attainment >=0.9 under a single-shard loss at 0.8x "
          "sustainable load (measured %.3f, %d failover(s), served+shed+"
          "failed==offered)"
        % (loss["attainment"], loss["failovers"]),
        ("PASS" if 0 < loss["served_p99_ms"] < float("inf") else "FAIL")
        + ": served-request p99 stays finite across the failover "
          "(%.0f ms; failover detected in %d ticks ~ %.1f ms)"
        % (loss["served_p99_ms"], loss["failover_detect_ticks"],
           loss["failover_detect_ms"]),
        ("PASS" if rec["bit_identical"] else "FAIL")
        + ": journal-replay recovery reproduces the source bit-identically "
          "(%d epochs / %d mutations in %.3f s = %.0f epochs/s)"
        % (rec["epochs"], rec["mutations"], rec["wall_s"],
           rec["epochs_per_s"]),
    ]
    return dict(identity=ident, loss=loss, recovery=rec, checks=checks,
                shape=shape, sustainable_qps=round(sustainable, 1))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    res = run(fast=args.fast)
    print("name,us_per_call,derived")
    print(f"fleet_identity,{res['identity']['n_responses']},"
          f"identical={res['identity']['identical']};"
          f"clock={res['identity']['clock_identical']}")
    l = res["loss"]
    print(f"fleet_shard_loss,{1e6 / max(l['served_qps'], 1e-9):.0f},"
          f"attain={l['attainment']:.3f};p99={l['served_p99_ms']:.0f}ms;"
          f"failovers={l['failovers']};detect={l['failover_detect_ticks']}t;"
          f"stale_served={l['stale_served']};failed={l['failed']}")
    r = res["recovery"]
    print(f"fleet_recovery,{r['wall_s'] * 1e6:.0f},"
          f"epochs={r['epochs']};eps={r['epochs_per_s']:.0f}/s;"
          f"bit_identical={r['bit_identical']}")
    for c in res["checks"]:
        print("#", c)


if __name__ == "__main__":
    main()
