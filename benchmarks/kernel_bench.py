"""Kernel micro-benchmarks: modmatmul server op (paper's hot loop).

CPU wall-times compare the exact-u32 XLA path against interpret-mode Pallas
(correctness path).  TPU projections come from the roofline model: the server
op moves m·n DB bytes and does 8·b int8-ops/byte; at v5e (394 TOPS int8,
819 GB/s HBM) the crossover is b ≈ 60 queries.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lwe, pir
from repro.kernels import ops, ref

V5E_INT8_OPS = 394e12
V5E_HBM = 819e9


def _time(fn, *args, iters=5) -> float:
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def run(sizes=((4096, 512), (16384, 1024), (65536, 2048)),
        batches=(1, 16, 64)) -> list[dict]:
    rows = []
    rng = np.random.default_rng(0)
    for m, n in sizes:
        db = jnp.asarray(rng.integers(0, 256, (m, n), dtype=np.uint8))
        for b in batches:
            q = jnp.asarray(rng.integers(0, 2**32, (n, b), dtype=np.uint32))
            xla_fn = jax.jit(lambda d, q: ref.modmatmul_ref(d, q))
            t_cpu = _time(xla_fn, db, q)
            ops_int8 = 8.0 * m * n * b           # 4 limbs × 2 (mul+add)
            tpu_compute = ops_int8 / V5E_INT8_OPS
            tpu_memory = (m * n) / V5E_HBM
            rows.append(dict(
                name=f"modmatmul_m{m}_n{n}_b{b}",
                us_per_call=t_cpu * 1e6,
                cpu_gbps=m * n / t_cpu / 1e9,
                tpu_bound="hbm" if tpu_memory > tpu_compute else "mxu",
                tpu_us_roofline=max(tpu_compute, tpu_memory) * 1e6,
                queries_per_s_tpu=b / max(tpu_compute, tpu_memory)))
    return rows


def run_protocol(m=16384, n=1024) -> list[dict]:
    """End-to-end protocol timings at one size (setup/query/answer/recover).
    The hint GEMM is a one-time O(m·n·k) cost; m capped so the CPU-exact
    u32 path stays in benchmark budget (TPU kernel does it at int8 rate)."""
    rng = np.random.default_rng(1)
    db = jnp.asarray(rng.integers(0, 256, (m, n), dtype=np.uint8))
    cfg = pir.make_config(m, n, impl="xla")
    server = pir.PIRServer(cfg, db)
    t_hint = _time(lambda: jax.block_until_ready(server.setup()), iters=1)
    hint = server.setup()
    client = pir.PIRClient(cfg, hint)
    qu, state = client.query(jax.random.PRNGKey(0), 3)
    t_query = _time(lambda: jax.block_until_ready(
        client.query(jax.random.PRNGKey(0), 3)[0]), iters=3)
    t_answer = _time(lambda: jax.block_until_ready(server.answer(qu)))
    ans = server.answer(qu)
    t_recover = _time(lambda: np.asarray(client.recover(ans, state)),
                      iters=3)
    return [
        dict(name="pir_hint_setup", us_per_call=t_hint * 1e6,
             derived=f"hint={cfg.hint_bytes / 2**20:.1f}MiB"),
        dict(name="pir_client_query", us_per_call=t_query * 1e6,
             derived=f"uplink={cfg.uplink_bytes}B"),
        dict(name="pir_server_answer", us_per_call=t_answer * 1e6,
             derived=f"db={m * n / 2**20:.0f}MiB"),
        dict(name="pir_client_recover", us_per_call=t_recover * 1e6,
             derived=f"downlink={cfg.downlink_bytes / 2**20:.2f}MiB"),
    ]
