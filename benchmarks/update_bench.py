"""Live-index freshness benchmark: delta-hint updates vs full offline rebuild.

For mutation batches touching a growing fraction of clusters, measure

  delta_s      — LiveIndex.commit() wall time (plan + column repack +
                 sparse ΔH GEMM + epoch publish)
  rebuild_s    — a from-scratch offline build of the same post-mutation
                 corpus (k-means + pack + full hint GEMM), the only way a
                 frozen-index deployment can absorb the batch
  patch_bytes  — client downlink to stay fresh (HintPatch wire bytes)
  hint_bytes   — what re-downloading the hint would cost instead

Acceptance (ISSUE 1): a batch touching ≤5% of clusters must commit ≥10×
faster than the rebuild, with patch_bytes ≪ hint_bytes.

    PYTHONPATH=src python -m benchmarks.update_bench [--fast]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def run(*, n_docs: int = 3000, n_clusters: int = 64, emb_dim: int = 48,
        fracs=(0.02, 0.05, 0.10, 0.25), seed: int = 0) -> list[dict]:
    from repro.data import corpus as corpus_lib
    from repro.core import pipeline
    from repro.update import LiveIndex

    corp = corpus_lib.make_corpus(seed, n_docs, emb_dim=emb_dim,
                                  n_topics=n_clusters)
    rows = []
    for frac in fracs:
        live = LiveIndex.build(corp.texts, corp.embeddings,
                               n_clusters=n_clusters, impl="xla")
        rng = np.random.default_rng(seed + 1)
        # one replace per targeted cluster: docs are picked from distinct
        # clusters so the batch touches ~frac·n columns
        n_target = max(1, int(round(frac * n_clusters)))
        targets = []
        seen = set()
        for doc in rng.permutation(n_docs):
            cl = int(live.system.assignment[doc])
            if cl not in seen:
                seen.add(cl)
                targets.append(int(doc))
            if len(targets) == n_target:
                break
        # warmup round: same batch size → same bucketed GEMM shape, so the
        # timed round below measures the steady-state streaming cost
        for doc in targets:
            live.replace(doc, f"warmup doc {doc}".encode(),
                         corp.embeddings[doc])
        live.commit()
        for doc in targets:
            live.replace(doc, f"refreshed doc {doc}".encode(),
                         corp.embeddings[doc])

        t0 = time.perf_counter()
        patch = live.commit()
        delta_s = time.perf_counter() - t0
        assert patch is not None and not patch.is_full

        ids = live.doc_ids()
        texts = [live._docs[i][0] for i in ids]
        embs = np.stack([live._docs[i][1] for i in ids])
        t0 = time.perf_counter()
        rebuilt = pipeline.PirRagSystem.build(texts, embs,
                                              n_clusters=n_clusters,
                                              impl="xla", doc_ids=ids)
        rebuild_s = time.perf_counter() - t0

        rows.append(dict(
            frac_clusters=len(patch.cols) / n_clusters,
            touched=len(patch.cols),
            delta_s=delta_s,
            rebuild_s=rebuild_s,
            speedup=rebuild_s / delta_s,
            patch_bytes=patch.wire_bytes,
            hint_bytes=live.system.cfg.hint_bytes,
            hint_ratio=patch.wire_bytes / live.system.cfg.hint_bytes,
            rebuilt_m=rebuilt.db.m))
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    kwargs = (dict(n_docs=800, n_clusters=16, emb_dim=24,
                   fracs=(0.0625, 0.25))
              if args.fast else {})
    rows = run(**kwargs)
    print("frac_clusters,touched,delta_s,rebuild_s,speedup,"
          "patch_bytes,hint_bytes,hint_ratio")
    for r in rows:
        print(f"{r['frac_clusters']:.3f},{r['touched']},{r['delta_s']:.4f},"
              f"{r['rebuild_s']:.3f},{r['speedup']:.1f},{r['patch_bytes']},"
              f"{r['hint_bytes']},{r['hint_ratio']:.2e}")
    small = [r for r in rows if r["frac_clusters"] <= 0.05 + 1e-9]
    for r in small:
        ok = r["speedup"] >= 10 and r["hint_ratio"] < 0.1
        print(f"{'PASS' if ok else 'FAIL'}: ≤5% batch — "
              f"{r['speedup']:.1f}× vs rebuild, patch is "
              f"{r['hint_ratio']:.3%} of the hint")


if __name__ == "__main__":
    main()
