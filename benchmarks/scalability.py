"""Paper Fig. 2: scalability of PIR-RAG / Tiptoe-style / Graph-PIR.

Sweeps corpus size and measures (a) one-time setup seconds, (b) end-to-end
query seconds, (c) uplink bytes, (d) downlink bytes — CPU-measured at reduced
scale; the claims validated are the *shapes and orderings* of the curves
(see EXPERIMENTS.md §Paper-validation).  TPU-scale server throughput comes
from the dry-run roofline instead.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import pipeline
from repro.core.baselines import graph_pir, tiptoe
from repro.data import corpus as corpus_lib


def run(sizes=(500, 1000, 2000, 4000), emb_dim=128, n_queries=3,
        seed=0) -> list[dict]:
    """emb_dim=128 matches the paper's SIFT1M scalability dataset; documents
    are ~0.8–1.6 KB (paper-like passages) so the downlink trade-off shows at
    its true magnitude."""
    rows = []
    for n_docs in sizes:
        corp = corpus_lib.make_corpus(seed, n_docs, emb_dim=emb_dim,
                                      n_topics=max(8, n_docs // 100),
                                      text_len=(800, 1600))
        n_clusters = max(4, int(np.sqrt(n_docs) / 2))

        # --- PIR-RAG ---------------------------------------------------------
        sysm = pipeline.PirRagSystem.build(corp.texts, corp.embeddings,
                                           n_clusters=n_clusters, impl="xla",
                                           seed=seed)
        qt, up, down = [], 0, 0
        for qi in range(n_queries):
            t0 = time.perf_counter()
            _, stats = sysm.query(corp.embeddings[qi * 7], top_k=10,
                                  key=jax.random.PRNGKey(qi))
            qt.append(time.perf_counter() - t0)
            up, down = stats.uplink_bytes, stats.downlink_bytes
        rows.append(dict(system="pir_rag", n_docs=n_docs,
                         setup_s=sysm.setup_seconds,
                         index_s=sysm.index_seconds,
                         hint_s=sysm.hint_seconds,
                         query_s=float(np.mean(qt)), uplink=up,
                         downlink=down))

        # --- Tiptoe-style ----------------------------------------------------
        tsys = tiptoe.TiptoeSystem.build(corp.embeddings,
                                         n_clusters=n_clusters, seed=seed)
        qt = []
        for qi in range(n_queries):
            t0 = time.perf_counter()
            _, st = tsys.search(corp.embeddings[qi * 7], top_k=10,
                                key=jax.random.PRNGKey(qi))
            qt.append(time.perf_counter() - t0)
        rows.append(dict(system="tiptoe", n_docs=n_docs,
                         setup_s=tsys.setup_seconds, index_s=tsys.setup_seconds,
                         query_s=float(np.mean(qt)), uplink=st.uplink_bytes,
                         downlink=st.downlink_bytes))

        # --- Graph-PIR -------------------------------------------------------
        gsys = graph_pir.GraphPIRSystem.build(corp.embeddings, degree=12,
                                              impl="xla", seed=seed)
        qt = []
        for qi in range(n_queries):
            t0 = time.perf_counter()
            _, st = gsys.search(corp.embeddings[qi * 7], top_k=10, beam=8,
                                max_hops=5, seed=qi)
            qt.append(time.perf_counter() - t0)
        rows.append(dict(system="graph_pir", n_docs=n_docs,
                         setup_s=gsys.setup_seconds,
                         index_s=gsys.index_seconds,
                         hint_s=gsys.hint_seconds,
                         query_s=float(np.mean(qt)), uplink=st.uplink_bytes,
                         downlink=st.downlink_bytes))
    return rows


def validate(rows: list[dict]) -> list[str]:
    """The paper's Fig-2 qualitative claims, checked programmatically."""
    by = lambda s: [r for r in rows if r["system"] == s]  # noqa: E731
    biggest = max(r["n_docs"] for r in rows)
    at = lambda s: next(r for r in by(s) if r["n_docs"] == biggest)  # noqa
    checks = []

    def check(name, ok):
        checks.append(f"{'PASS' if ok else 'FAIL'}  {name}")

    # Fig 2a is a GROWTH claim: graph construction is superlinear in corpus
    # size while clustering is ~linear.  Absolute constants at ≤5k docs are
    # BLAS artifacts (vectorized brute-force kNN is cheap; the crypto hint
    # GEMM dominates PIR-RAG's CPU setup but runs at the int8 roofline on
    # the TPU target — 0.7 ms at production scale, §Roofline).
    smallest = min(r["n_docs"] for r in rows)
    at0 = lambda s: next(r for r in by(s) if r["n_docs"] == smallest)  # noqa
    growth = lambda s: (at(s)["index_s"]  # noqa: E731
                        / max(at0(s)["index_s"], 1e-3))
    check("graph index build grows superlinearly vs cluster build (Fig 2a)",
          growth("graph_pir") > 4.0
          and growth("graph_pir") > 2 * growth("pir_rag"))
    check("pir_rag uplink smallest (Fig 2c)",
          at("pir_rag")["uplink"] <= at("graph_pir")["uplink"])
    check("pir_rag downlink largest by far (Fig 2d)",
          at("pir_rag")["downlink"] > 10 * at("tiptoe")["downlink"]
          and at("pir_rag")["downlink"] > 10 * at("graph_pir")["downlink"])
    pr = by("pir_rag")
    check("pir_rag downlink grows with corpus (Fig 2d trend)",
          pr[-1]["downlink"] > pr[0]["downlink"])
    gq = by("graph_pir")
    check("graph query time ~flat vs corpus (Fig 2b)",
          gq[-1]["query_s"] < 4 * max(gq[0]["query_s"], 1e-3))
    return checks
