"""Serving-engine benchmark: pipelined vs synchronous under mutation load.

Drives the two engines in `repro.serve` over the SAME open-loop workload —
requests submitted one per tick, a live-index replace every
``mutate_every`` requests — and measures

  throughput_qps — served requests / wall (retries are extra work, not
                   extra credit: only distinct rids count)
  p50/p99_ms     — per-request completion latency (t_done − t_arrival;
                   the pipelined engine stamps these at its complete stage)
  stage/swap_s   — shadow-commit accounting: patch compute vs the pointer
                   swap that is the only stale window

The engines produce BIT-IDENTICAL responses (asserted in-loop: payloads,
epochs, retry counts); the pipelined one just overlaps batch N's answer
GEMM with decoding batch N−depth, encoding batch N+1, and the shadow
commit's delta GEMMs — plus donated in-place DB patches instead of a full
copy per epoch.  Acceptance (ISSUE 4): ≥1.5× sustained throughput under
mutation load with p99 no worse.

    PYTHONPATH=src python -m benchmarks.serve_bench [--fast]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _drive(loop, corp, *, n_req: int, mutate_every: int, max_batch: int,
           journal_lib) -> dict:
    """Warm up compile caches, then run the timed open-loop workload."""
    n_docs = len(corp.texts)
    rng = np.random.default_rng(3)
    # warmup: one full batch + one commit so both engines enter the timed
    # region with every GEMM shape compiled
    for rid in range(max_batch):
        loop.submit(1_000_000 + rid, corp.embeddings[rid])
    if mutate_every:
        loop.submit_mutation(journal_lib.replace(
            0, b"warmup", corp.embeddings[0]))
    loop.drain()
    n_warm = len(loop.responses)
    retries_warm = loop.stale_retries

    arrivals: dict[int, float] = {}
    depth_peak, age_peak_ms = 0, 0.0
    t0 = time.perf_counter()
    for rid in range(n_req):
        arrivals[rid] = time.perf_counter()
        loop.submit(rid, corp.embeddings[int(rng.integers(0, n_docs))])
        if mutate_every and rid % mutate_every == 0:
            d = int(rng.integers(0, n_docs))
            loop.submit_mutation(journal_lib.replace(
                d, f"refreshed {d}@{rid}".encode(), corp.embeddings[d]))
        # backlog observability (ISSUE 6): peak queue depth and peak head
        # age, sampled at the worst instant — just before the tick serves
        depth_peak = max(depth_peak, loop.batcher.depth)
        age_peak_ms = max(age_peak_ms,
                          loop.batcher.oldest_age_ms(time.perf_counter()))
        loop.tick()
    loop.drain()
    wall = time.perf_counter() - t0

    resp = loop.responses[n_warm:]
    lat_ms = [(r.t_done - arrivals[r.rid]) * 1e3 for r in resp]
    sig = [(r.rid, r.epoch, r.retries, r.batch_size,
            tuple((d, t) for d, _, t in r.top)) for r in resp]
    return dict(wall_s=wall, served=len(resp),
                throughput_qps=len(resp) / wall,
                p50_ms=float(np.percentile(lat_ms, 50)),
                p99_ms=float(np.percentile(lat_ms, 99)),
                retries=loop.stale_retries - retries_warm,
                epochs=loop.epoch,
                queue_depth_peak=depth_peak,
                oldest_age_peak_ms=round(age_peak_ms, 3),
                _sig=sig)


def run(*, fast: bool = False) -> dict:
    from repro.data import corpus as corpus_lib
    from repro.serve import PIRServeLoop, PipelinedServeLoop
    from repro.update import LiveIndex, journal as journal_lib

    if fast:
        shape = dict(n_docs=2000, n_clusters=128, emb_dim=48, max_batch=16,
                     n_req=96, mutate_every=8, depth=2, kmeans_iters=8)
    else:
        shape = dict(n_docs=4000, n_clusters=256, emb_dim=48, max_batch=32,
                     n_req=192, mutate_every=8, depth=2, kmeans_iters=8)
    corp = corpus_lib.make_corpus(0, shape["n_docs"],
                                  emb_dim=shape["emb_dim"],
                                  n_topics=shape["n_clusters"])

    def build():
        return LiveIndex.build(corp.texts, corp.embeddings,
                               n_clusters=shape["n_clusters"], impl="xla",
                               kmeans_iters=shape["kmeans_iters"])

    rows, sigs = [], {}
    for mutate_every in (shape["mutate_every"], 0):
        for engine in ("sync", "pipelined"):
            live = build()
            if engine == "sync":
                loop = PIRServeLoop(live, max_batch=shape["max_batch"],
                                    deadline_ms=1e9, seed=0)
            else:
                loop = PipelinedServeLoop(live, max_batch=shape["max_batch"],
                                          deadline_ms=1e9, seed=0,
                                          depth=shape["depth"], donate=True)
            r = _drive(loop, corp, n_req=shape["n_req"],
                       mutate_every=mutate_every,
                       max_batch=shape["max_batch"],
                       journal_lib=journal_lib)
            sigs[(engine, mutate_every)] = r.pop("_sig")
            r.update(engine=engine, mutate_every=mutate_every)
            if engine == "pipelined" and loop._shadow is not None:
                r.update(commit_stage_s=loop._shadow.stage_seconds,
                         commit_swap_s=loop._shadow.swap_seconds)
            rows.append(r)

    def row(engine, mut):
        return next(r for r in rows
                    if r["engine"] == engine and r["mutate_every"] == mut)

    mut = shape["mutate_every"]
    ratio = (row("pipelined", mut)["throughput_qps"]
             / row("sync", mut)["throughput_qps"])
    # 5% allowance for wall-clock measurement noise, and the check message
    # states it — a larger regression must FAIL, not hide behind slack
    p99_ok = (row("pipelined", mut)["p99_ms"]
              <= 1.05 * row("sync", mut)["p99_ms"])
    identical = all(sigs[("sync", m)] == sigs[("pipelined", m)]
                    for m in (mut, 0))
    checks = [
        ("PASS" if ratio >= 1.5 else "FAIL")
        + ": pipelined engine sustains >=1.5x query throughput under "
        + "mutation load vs the synchronous loop (measured %.2fx)" % ratio,
        ("PASS" if p99_ok else "FAIL")
        + ": pipelined p99 completion latency no worse than synchronous "
        + "within 5%% measurement noise (%.0f vs %.0f ms)"
        % (row("pipelined", mut)["p99_ms"], row("sync", mut)["p99_ms"]),
        ("PASS" if identical else "FAIL")
        + ": pipelined responses bit-identical to the synchronous loop "
        + "(payloads, epochs, retries) with and without mutations",
    ]
    return dict(rows=rows, checks=checks, shape=shape,
                throughput_ratio=ratio)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    res = run(fast=args.fast)
    print("name,us_per_call,derived")
    for r in res["rows"]:
        print(f"serve_{r['engine']}_mut{r['mutate_every']},"
              f"{1e6 / r['throughput_qps']:.0f},"
              f"qps={r['throughput_qps']:.1f};p50={r['p50_ms']:.0f}ms;"
              f"p99={r['p99_ms']:.0f}ms;retries={r['retries']};"
              f"qdepth={r['queue_depth_peak']};"
              f"qage={r['oldest_age_peak_ms']:.1f}ms")
    for c in res["checks"]:
        print("#", c)


if __name__ == "__main__":
    main()
