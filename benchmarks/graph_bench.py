"""Graph-PIR sketch tuning sweep: width vs record size vs recall.

The Graph-PIR baseline ranks traversal candidates by a SimHash sketch of
each neighbour carried inside every PIR-fetched node record.  The sketch
width is the tuning knob: wider sketches estimate cosine similarity more
tightly (better fetch targeting → higher recall per hop budget) but every
neighbour costs `bits/8` extra bytes in every record, which inflates the
PIR record size m — and with it per-fetch downlink and the server GEMM.
This sweep measures the trade-off over widths 16..128 against brute-force
cosine ground truth.

    PYTHONPATH=src python -m benchmarks.graph_bench [--fast]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

SKETCH_BITS = (16, 32, 64, 128)


def _ground_truth(embs: np.ndarray, queries: np.ndarray,
                  top_k: int) -> np.ndarray:
    nn = embs / (np.linalg.norm(embs, axis=1, keepdims=True) + 1e-12)
    qn = queries / (np.linalg.norm(queries, axis=1, keepdims=True) + 1e-12)
    return np.argsort(-(qn @ nn.T), axis=1)[:, :top_k]


def run(*, fast: bool = False) -> dict:
    from repro.core.baselines.graph_pir import GraphPIRSystem
    from repro.data import corpus as corpus_lib

    if fast:
        shape = dict(n_docs=500, emb_dim=32, n_queries=8, top_k=10,
                     beam=8, max_hops=5, degree=10, n_random=4)
    else:
        shape = dict(n_docs=1500, emb_dim=48, n_queries=12, top_k=10,
                     beam=8, max_hops=6, degree=12, n_random=4)
    corp = corpus_lib.make_corpus(4, shape["n_docs"],
                                  emb_dim=shape["emb_dim"], n_topics=24)
    rng = np.random.default_rng(4)
    qidx = rng.choice(shape["n_docs"], shape["n_queries"], replace=False)
    queries = (corp.embeddings[qidx]
               + 0.05 * rng.standard_normal(
                   (shape["n_queries"], shape["emb_dim"])
               ).astype(np.float32))
    truth = _ground_truth(corp.embeddings, queries, shape["top_k"])

    rows = []
    for bits in SKETCH_BITS:
        sys_ = GraphPIRSystem.build(
            corp.embeddings, degree=shape["degree"],
            n_random=shape["n_random"], impl="xla", seed=0,
            sketch_bits=bits)
        recalls, fetched, hops, q_s = [], 0, 0, 0.0
        for qi in range(shape["n_queries"]):
            t0 = time.perf_counter()
            ids, st = sys_.search(queries[qi], top_k=shape["top_k"],
                                  beam=shape["beam"],
                                  max_hops=shape["max_hops"], seed=qi)
            q_s += time.perf_counter() - t0
            recalls.append(len(set(ids) & set(truth[qi]))
                           / shape["top_k"])
            fetched += st.fetched_nodes
            hops += st.hops
        deg = shape["degree"] + shape["n_random"]
        rows.append(dict(
            sketch_bits=bits,
            record_bytes=sys_.cfg.m,
            sketch_bytes_per_record=deg * bits // 8,
            hint_bytes=sys_.cfg.hint_bytes,
            downlink_per_fetch=sys_.cfg.downlink_bytes,
            recall10=round(float(np.mean(recalls)), 4),
            fetched_per_query=round(fetched / shape["n_queries"], 1),
            hops_per_query=round(hops / shape["n_queries"], 2),
            query_s=round(q_s / shape["n_queries"], 4)))

    # record layout: scale/off floats + quantized emb + ids + sketches
    deg = shape["degree"] + shape["n_random"]
    layout_ok = all(
        r["record_bytes"] == 8 + shape["emb_dim"] + deg * 4
        + deg * r["sketch_bits"] // 8 for r in rows)
    by_bits = {r["sketch_bits"]: r for r in rows}
    wide, narrow = by_bits[max(SKETCH_BITS)], by_bits[min(SKETCH_BITS)]
    knee = by_bits[64]
    checks = [
        ("PASS" if layout_ok else "FAIL")
        + ": record bytes follow the serialization layout exactly at every "
          "sketch width (8 + d + deg*(4 + bits/8))",
        ("PASS" if wide["recall10"] >= narrow["recall10"] else "FAIL")
        + ": widest sketch (128b) recalls at least as well as the "
          "narrowest (16b): %.2f vs %.2f"
        % (wide["recall10"], narrow["recall10"]),
        ("PASS" if knee["recall10"] >= wide["recall10"] - 0.1
         and knee["record_bytes"] < wide["record_bytes"] else "FAIL")
        + ": 64-bit sketches sit at the knee — within 0.1 recall of 128b "
          "(%.2f vs %.2f) at %d vs %d record bytes"
        % (knee["recall10"], wide["recall10"], knee["record_bytes"],
           wide["record_bytes"]),
    ]
    return dict(rows=rows, checks=checks, shape=shape)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    res = run(fast=args.fast)
    print("name,us_per_call,derived")
    for r in res["rows"]:
        print(f"graph_sketch{r['sketch_bits']},{r['query_s'] * 1e6:.0f},"
              f"recall10={r['recall10']:.3f};rec_bytes={r['record_bytes']};"
              f"fetched={r['fetched_per_query']};hops={r['hops_per_query']}")
    for c in res["checks"]:
        print("#", c)


if __name__ == "__main__":
    main()
