"""Open-loop traffic benchmark: SLO attainment, hint delivery, admission.

Three experiments over the live-index + pipelined-engine stack (ISSUE 6):

load sweep      Calibrate the engine's sustainable closed-loop throughput,
                then offer open-loop Poisson traffic at 0.5×/0.8×/1.2× of
                it (mutations riding along) and report SLO summaries —
                attainment at the deadline, p50/p99, and the per-request
                component breakdown (queue/encode/gemm/decode/hint-sync).

hint delivery   A client stranded 8 epochs behind a log compacted at
                ``compact_every=4`` downloads the compacted chain — two
                segments instead of eight patches — decodes bit-identically
                to the live hint, and pays ≤10% of a full hint re-download.

admission       At 1.2× sustainable the queue cannot drain: the controller
                sheds the tail, defers commits under backlog and deepens
                the pipeline.  The checks are structural (exact
                accounting: served + shed == offered; served-tail finite)
                rather than wall-clock thresholds.

    PYTHONPATH=src python -m benchmarks.traffic_bench [--fast]
"""
from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

LOAD_FACTORS = (0.5, 0.8, 1.2)


def _mutator_for(corp):
    """Same-embedding replaces: steady patch traffic, stable clustering."""
    from repro.update import journal as journal_lib
    n = len(corp.texts)

    def mutator(rng):
        d = int(rng.integers(n))
        return journal_lib.replace(d, f"refresh {d}".encode(),
                                   corp.embeddings[d])
    return mutator


def _make_loop(live, shape):
    from repro.serve import PipelinedServeLoop
    return PipelinedServeLoop(live, max_batch=shape["max_batch"],
                              deadline_ms=shape["loop_deadline_ms"],
                              depth=2, donate=True, seed=0)


def _warmup(live, corp, shape, mutator):
    """Compile every GEMM shape the sweep will hit before any timing.

    The answer GEMM's width is the batch width, and XLA compiles per
    width: deadline cuts produce every width from 1 to max_batch (for
    both probe groups), so an unwarmed sweep measures the compiler, not
    the engine.  One commit warms the delta-staging shapes too.
    """
    loop = _make_loop(live, shape)
    rid = 10_000_000
    for mp in (1, 4):
        for width in range(1, shape["max_batch"] + 1):
            for _ in range(width):
                loop.submit(rid, corp.embeddings[rid % len(corp.texts)],
                            multi_probe=mp)
                rid += 1
            loop.drain()
    loop.submit_mutation(mutator(np.random.default_rng(99)))
    loop.drain()


def _calibrate(live, corp, shape, mutator) -> tuple[float, float]:
    """Sustainable open-loop qps for THIS workload mix; and commit cost.

    Closed-loop service rate over the sweep's own 75/25 single/multi-probe
    mix, derated by the fraction of each second the configured mutation
    rate spends inside epoch commits (measured, not assumed — a commit
    stages delta GEMMs and batch-PIR patches, which is serving downtime).
    """
    loop = _make_loop(live, shape)
    rng = np.random.default_rng(0)
    n_docs = len(corp.texts)
    n = shape["calibrate_n"]
    t0 = time.perf_counter()
    for rid in range(n):
        loop.submit(rid, corp.embeddings[int(rng.integers(n_docs))],
                    multi_probe=4 if rid % 4 == 0 else 1)
        loop.tick()
    loop.drain()
    mixed_qps = n / (time.perf_counter() - t0)
    t0 = time.perf_counter()
    loop.submit_mutation(mutator(rng))
    loop.drain()
    commit_s = time.perf_counter() - t0
    # raw mixed rate EXCLUDES commit downtime, so it upper-bounds what the
    # mutation-carrying sweep can actually sustain: offering 1.2× of it is
    # overload by construction, while the derated estimate below is the
    # honest "sustainable with commits" number the report carries
    frac_serving = max(0.2, 1.0 - shape["mutation_qps"] * commit_s)
    return mixed_qps, mixed_qps * frac_serving, commit_s


def _run_point(live, corp, shape, qps: float, factor: float,
               mutator) -> dict:
    from repro.traffic import AdmissionController, OpenLoopDriver, TrafficSpec
    loop = _make_loop(live, shape)
    ctl = AdmissionController(max_queue=shape["max_queue"],
                              max_depth=4)
    # same seed at every factor: independent arrival streams mean the
    # mutation schedule is IDENTICAL across load points (same commit
    # pressure), only the query rate changes
    spec = TrafficSpec(qps=qps, duration_s=shape["duration_s"],
                       n_sessions=shape["n_sessions"],
                       probe_mix=((1, 0.75), (4, 0.25)),
                       staleness_tolerance=shape["staleness_tolerance"],
                       mutation_qps=shape["mutation_qps"],
                       seed=7)
    res = OpenLoopDriver(loop, corp.embeddings, spec, mutator=mutator,
                         controller=ctl).run()
    s = res.summary(deadline_ms=shape["deadline_ms"])
    s["load_factor"] = factor
    served = [r for r in res.records if r.outcome == "served"]
    lat = sorted(r.latency_ms for r in served)
    s["served_p99_ms"] = (round(lat[int(np.ceil(0.99 * len(lat))) - 1], 3)
                          if lat else 0.0)
    return s


def _chain_demo(fast: bool) -> dict:
    """8 commits, compact_every=4: the stranded client's downlink."""
    from repro.data import corpus as corpus_lib
    from repro.update import HintCache, LiveIndex
    import jax.numpy as jnp

    n_docs = 800 if fast else 2000
    n_clusters = 64 if fast else 128
    corp = corpus_lib.make_corpus(1, n_docs, emb_dim=32,
                                  n_topics=n_clusters)
    live = LiveIndex.build(corp.texts, corp.embeddings,
                           n_clusters=n_clusters, impl="xla",
                           kmeans_iters=6, compact_every=4)
    h0, cfg0 = np.asarray(live.system.hint), live.system.cfg
    rng = np.random.default_rng(2)
    commits = 0
    while commits < 8:
        for _ in range(2):
            d = int(rng.integers(n_docs))
            live.replace(d, f"v{commits} {d}".encode(), corp.embeddings[d])
        if live.commit() is not None:
            commits += 1
    log = live.epochs
    chain = log.chain_since(0)
    raw = log.patches_since(0)
    cache = HintCache(h0, cfg0, epoch=0)
    sync_bytes = cache.sync(log)
    identical = bool(jnp.array_equal(jnp.asarray(cache.hint),
                                     live.system.hint))
    return dict(epochs_behind=log.epoch,
                chain_patches=len(chain),
                raw_patches=len(raw),
                chain_bytes=log.chain_bytes(0),
                raw_bytes=sum(p.wire_bytes for p in raw),
                sync_bytes=sync_bytes,
                full_hint_bytes=cfg0.hint_bytes,
                frac_of_full=round(sync_bytes / cfg0.hint_bytes, 4),
                bit_identical=identical,
                stored_bytes=log.stored_bytes)


def run(*, fast: bool = False) -> dict:
    from repro.data import corpus as corpus_lib
    from repro.update import LiveIndex

    if fast:
        shape = dict(n_docs=1500, n_clusters=96, emb_dim=48, max_batch=16,
                     calibrate_n=96, duration_s=2.0, n_sessions=16,
                     mutation_qps=1.0, staleness_tolerance=2, max_queue=24,
                     loop_deadline_ms=10.0, deadline_ms=400.0,
                     kmeans_iters=8)
    else:
        shape = dict(n_docs=4000, n_clusters=256, emb_dim=48, max_batch=32,
                     calibrate_n=160, duration_s=3.0, n_sessions=32,
                     mutation_qps=1.0, staleness_tolerance=2, max_queue=48,
                     loop_deadline_ms=10.0, deadline_ms=400.0,
                     kmeans_iters=8)
    corp = corpus_lib.make_corpus(0, shape["n_docs"],
                                  emb_dim=shape["emb_dim"],
                                  n_topics=shape["n_clusters"])
    live = LiveIndex.build(corp.texts, corp.embeddings,
                           n_clusters=shape["n_clusters"], impl="xla",
                           kmeans_iters=shape["kmeans_iters"],
                           compact_every=4)
    live.system.enable_batch(kappa=4)
    mutator = _mutator_for(corp)

    _warmup(live, corp, shape, mutator)
    mixed_qps, sustainable, commit_s = _calibrate(live, corp, shape, mutator)
    # sub-capacity points are scaled from the derated (with-commits)
    # sustainable rate; the overload point from the RAW mixed rate, which
    # commit downtime makes unsustainable by construction
    rows = [_run_point(live, corp, shape,
                       (sustainable if f < 1.0 else mixed_qps) * f,
                       f, mutator)
            for f in LOAD_FACTORS]
    chain = _chain_demo(fast)

    low, over = rows[0], rows[-1]
    accounted = all(r["served"] + r["shed"] == r["offered"] for r in rows)
    checks = [
        ("PASS" if low["attainment"] >= 0.9 else "FAIL")
        + ": open-loop SLO attainment >=0.9 at 0.5x sustainable load "
        + "(measured %.3f at %.0f qps offered, deadline %dms)"
        % (low["attainment"], low["offered_qps"], int(low["deadline_ms"])),
        ("PASS" if chain["frac_of_full"] <= 0.10 and chain["bit_identical"]
         else "FAIL")
        + ": client 8 epochs stale syncs a compacted chain (%d segments vs "
          "%d raw patches) costing %.1f%% of a full hint re-download, "
          "decoding bit-identically"
        % (chain["chain_patches"], chain["raw_patches"],
           100 * chain["frac_of_full"]),
        ("PASS" if over["shed"] > 0 and accounted else "FAIL")
        + ": at 1.2x sustainable the admission controller sheds load "
          "(%d shed, %d deferred commits) and every offered request is "
          "accounted served or shed"
        % (over["shed"], over["admission"]["deferred_commits"]),
        ("PASS" if over["served_p99_ms"] < float("inf")
         and over["served_p99_ms"] > 0 else "FAIL")
        + ": served-request p99 stays finite under overload "
          "(%.0f ms with the queue capped at %d)"
        % (over["served_p99_ms"], shape["max_queue"]),
    ]
    return dict(rows=rows, chain=chain, checks=checks, shape=shape,
                mixed_qps=round(mixed_qps, 1),
                sustainable_qps=round(sustainable, 1),
                commit_s=round(commit_s, 4))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    res = run(fast=args.fast)
    print("name,us_per_call,derived")
    print(f"traffic_sustainable,{1e6 / res['sustainable_qps']:.0f},"
          f"sustainable_qps={res['sustainable_qps']:.1f};"
          f"mixed_qps={res['mixed_qps']:.1f};"
          f"commit_s={res['commit_s']:.3f}")
    for r in res["rows"]:
        c = r["components"]
        print(f"traffic_load{r['load_factor']},"
              f"{1e6 / max(r['served_qps'], 1e-9):.0f},"
              f"attain={r['attainment']:.3f};p50={r['p50_ms']:.0f}ms;"
              f"served_p99={r['served_p99_ms']:.0f}ms;"
              f"shed={r['shed']};retries={r['stale_retries']};"
              f"queue={c['queue_ms']['mean']:.1f}ms;"
              f"gemm={c['gemm_ms']['mean']:.2f}ms;"
              f"hint={c['hint_sync_ms']['mean']:.3f}ms")
    ch = res["chain"]
    print(f"traffic_hint_chain,{ch['sync_bytes']},"
          f"frac_of_full={ch['frac_of_full']:.4f};"
          f"chain={ch['chain_patches']};raw={ch['raw_patches']};"
          f"bit_identical={ch['bit_identical']}")
    for c in res["checks"]:
        print("#", c)


if __name__ == "__main__":
    main()
