"""Paper Fig. 3: search quality + retrieval-phase latency + RAG-Ready latency.

Fixed-size corpus (5k docs as in the paper, synthetic labels — DESIGN.md
§Known deviations #1).  Reports NDCG@10 / P@10 / R@50 per system, the raw
retrieval time, and the paper's headline metric: *RAG-Ready latency*, i.e.
time until full document content is client-side — which charges Graph-PIR
and Tiptoe their K extra private content fetches (DocContentPIR).

Variance note: single-cluster retrieval quality is sensitive to the K-means
draw — a single build's NDCG@10 swings ±0.05 with clustering luck, and at
CI-sized corpora PIR-RAG and Tiptoe sit close enough that one draw flips
the Fig-3a hierarchy sign.  The claim is about the systems' EXPECTED
quality, so both cluster-seeded systems are averaged over ``n_builds``
build seeds (measured: the averaged estimator orders them consistently
across corpus seeds where single draws coin-flip).  Graph-PIR's margin is
wide and its graph build is the expensive one, so it stays single-build.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import pipeline
from repro.core.baselines import common, graph_pir, tiptoe
from repro.data import corpus as corpus_lib
from repro.data import metrics


def run(n_docs=5000, emb_dim=384, n_queries=12, top_k=10, seed=0,
        n_builds=3) -> list[dict]:
    """Benchmark regime (why these numbers — see EXPERIMENTS.md):

    * emb_dim=384 (bge-small class): Tiptoe's homomorphic scoring must fit
      Σd·q in the plaintext modulus → ~6 signed quantization levels.
    * encoder_noise=0.3: ground-truth relevance lives in a latent space the
      encoder renders imperfectly, so relevant docs straddle cluster cells —
      the regime where fine-grained graph traversal out-recalls single-
      cluster pruning (the paper's Fig-3 hierarchy).
    * ~15 docs/cluster: the paper-scale cluster granularity; top-10 then
      crosses cell boundaries for boundary queries.
    """
    corp = corpus_lib.make_corpus(seed, n_docs, emb_dim=emb_dim, n_topics=50,
                                  topic_spread=1.0, encoder_noise=0.3)
    qs = corpus_lib.make_queries(seed + 1, corp, n_queries, n_relevant=30,
                                 noise=0.4, topical=False)
    n_clusters = max(8, n_docs // 15)

    gsys = graph_pir.GraphPIRSystem.build(corp.embeddings, degree=24,
                                          n_entry=16, impl="xla", seed=seed)
    # the content store both baselines must hit for RAG (retrieve-THEN-fetch)
    content = common.DocContentPIR.build(corp.texts, corp.embeddings,
                                         impl="xla")

    out = {s: dict(system=s, ndcg=[], p=[], r=[], t_retrieval=[],
                   t_rag_ready=[])
           for s in ("pir_rag", "tiptoe", "graph_pir")}

    for bi in range(max(1, n_builds)):
        bseed = seed + 100 * bi
        sysm = pipeline.PirRagSystem.build(corp.texts, corp.embeddings,
                                           n_clusters=n_clusters, impl="xla",
                                           seed=bseed)
        tsys = tiptoe.TiptoeSystem.build(corp.embeddings,
                                         n_clusters=n_clusters, seed=bseed)
        for qi in range(n_queries):
            q = qs.embeddings[qi]
            rel, gains = qs.relevant[qi], qs.gains[qi]

            t0 = time.perf_counter()
            top, _ = sysm.query(q, top_k=top_k, key=jax.random.PRNGKey(qi))
            t1 = time.perf_counter()
            ids = np.array([d for d, _, _ in top])
            _score(out["pir_rag"], ids, rel, gains, top_k, t1 - t0,
                   t1 - t0)                       # content already in hand

            t0 = time.perf_counter()
            ids, _ = tsys.search(q, top_k=top_k, key=jax.random.PRNGKey(qi))
            t1 = time.perf_counter()
            content.fetch_many(qi, ids[:top_k])   # K more private fetches
            t2 = time.perf_counter()
            _score(out["tiptoe"], ids, rel, gains, top_k, t1 - t0, t2 - t0)

            if bi > 0:
                continue        # graph has no cluster seed; one pass suffices
            t0 = time.perf_counter()
            ids, _ = gsys.search(q, top_k=top_k, beam=32, max_hops=12,
                                 seed=qi)
            t1 = time.perf_counter()
            content.fetch_many(1000 + qi, ids[:top_k])
            t2 = time.perf_counter()
            _score(out["graph_pir"], ids, rel, gains, top_k, t1 - t0,
                   t2 - t0)

    rows = []
    for s, d in out.items():
        rows.append(dict(system=s,
                         ndcg10=float(np.mean(d["ndcg"])),
                         p10=float(np.mean(d["p"])),
                         r50=float(np.mean(d["r"])),
                         t_retrieval_s=float(np.mean(d["t_retrieval"])),
                         t_rag_ready_s=float(np.mean(d["t_rag_ready"]))))
    return rows


def _score(d, ids, rel, gains, k, t_ret, t_ready):
    d["ndcg"].append(metrics.ndcg_at_k(ids, rel, gains, k))
    d["p"].append(metrics.precision_at_k(ids, rel, k))
    d["r"].append(metrics.recall_at_k(ids, rel, 50))
    d["t_retrieval"].append(t_ret)
    d["t_rag_ready"].append(t_ready)


def validate(rows: list[dict]) -> list[str]:
    at = {r["system"]: r for r in rows}
    checks = []

    def check(name, ok):
        checks.append(f"{'PASS' if ok else 'FAIL'}  {name}")

    check("quality hierarchy graph > pir_rag > tiptoe (Fig 3a)",
          at["graph_pir"]["ndcg10"] >= at["pir_rag"]["ndcg10"]
          >= at["tiptoe"]["ndcg10"])
    check("pir_rag quality is competitive (≥0.6 NDCG@10)",
          at["pir_rag"]["ndcg10"] >= 0.6)
    check("tiptoe quality degraded by coarse quantization",
          at["tiptoe"]["ndcg10"] < at["pir_rag"]["ndcg10"])
    check("RAG-Ready: pir_rag pays no fetch tail",
          abs(at["pir_rag"]["t_rag_ready_s"]
              - at["pir_rag"]["t_retrieval_s"]) < 1e-6)
    check("RAG-Ready: baselines pay K-fetch tail (Fig 3c story)",
          at["tiptoe"]["t_rag_ready_s"] > at["tiptoe"]["t_retrieval_s"]
          and at["graph_pir"]["t_rag_ready_s"]
          > at["graph_pir"]["t_retrieval_s"])
    return checks
