"""Batched PIR serving: many concurrent private clients, one server GEMM.

    PYTHONPATH=src python examples/serve_pir.py --clients 32

Simulates a serving tick: B clients each privately fetch a (different,
secret) cluster; the server stacks the encrypted queries into one modular
GEMM — the batching that makes the TPU kernel MXU-bound (see roofline).
Every client's recovered content is verified byte-exact.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import pipeline  # noqa: E402
from repro.data import corpus as corpus_lib  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--clients", type=int, default=32)
    ap.add_argument("--docs", type=int, default=2000)
    args = ap.parse_args()

    corp = corpus_lib.make_corpus(3, n_docs=args.docs, emb_dim=64,
                                  n_topics=24)
    system = pipeline.PirRagSystem.build(corp.texts, corp.embeddings,
                                         n_clusters=24, impl="xla")
    rng = np.random.default_rng(0)
    queries = corp.embeddings[rng.integers(0, args.docs, args.clients)]

    t0 = time.perf_counter()
    results = system.query_batch(queries, top_k=3, seed=7)
    dt = time.perf_counter() - t0

    ok = 0
    for res in results:
        for doc_id, _, text in res:
            assert text == corp.texts[doc_id]
            ok += 1
    per_client_down = system.cfg.downlink_bytes / 2**20
    print(f"{args.clients} private clients served in {dt:.2f}s "
          f"({dt / args.clients * 1e3:.1f} ms/client amortized)")
    print(f"verified {ok} returned documents byte-exact")
    print(f"per-client: uplink {system.cfg.uplink_bytes} B, "
          f"downlink {per_client_down:.2f} MiB")
    print("server saw only uint32 noise — no query, cluster, or result.")


if __name__ == "__main__":
    main()
