"""Architecture shoot-out on one corpus: PIR-RAG vs Graph-PIR vs Tiptoe.

    PYTHONPATH=src python examples/compare_baselines.py

Prints the paper's Fig-3-style table: quality, retrieval latency, and
RAG-Ready latency (content in hand).
"""
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks import quality  # noqa: E402


def main():
    rows = quality.run(n_docs=1500, n_queries=10)
    print(f"{'system':<12}{'NDCG@10':>9}{'P@10':>7}{'R@50':>7}"
          f"{'retrieval s':>13}{'RAG-ready s':>13}")
    for r in rows:
        print(f"{r['system']:<12}{r['ndcg10']:>9.3f}{r['p10']:>7.3f}"
              f"{r['r50']:>7.3f}{r['t_retrieval_s']:>13.3f}"
              f"{r['t_rag_ready_s']:>13.3f}")
    print()
    for c in quality.validate(rows):
        print(" ", c)
    print("\nNote: this example runs a REDUCED corpus for speed; quality "
          "orderings at this size are noisy.\nThe paper-claim validation of "
          "record runs at full scale via `python -m benchmarks.run`\n"
          "(see bench_output.txt: 10/10 PASS).")


if __name__ == "__main__":
    main()
