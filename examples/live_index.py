"""Live-index demo: private retrieval over a corpus that never stops moving.

Walks the full lifecycle:

  1. build a PIR-RAG system and wrap it in a LiveIndex
  2. a client bootstraps a HintCache (one full hint download)
  3. stream insert / replace / delete batches; each commit publishes a
     versioned epoch with a sparse HintPatch
  4. the client syncs its cache from the patch log (KB, not MB) and
     privately retrieves the *updated* content
  5. a burst of deletes degrades pad_fraction and forces a full rebuild —
     the one case where the client re-downloads the hint

    PYTHONPATH=src python examples/live_index.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.data import corpus as corpus_lib
from repro.update import HintCache, LiveIndex


def kb(b):
    return f"{b / 1024:.1f} KB"


def main():
    corp = corpus_lib.make_corpus(0, 600, emb_dim=32, n_topics=12)
    live = LiveIndex.build(corp.texts, corp.embeddings, n_clusters=12,
                           impl="xla", max_pad_fraction=0.8)
    cache = HintCache(live.system.hint, live.system.cfg)
    print(f"built: {live.n_docs} docs, n={live.system.db.n} clusters, "
          f"m={live.system.db.m}; hint download {kb(cache.bytes_downloaded)}")

    # -- streaming mutations -------------------------------------------------
    live.insert(9001, b"breaking: newly published document", corp.embeddings[3])
    live.replace(42, b"doc 42, revised edition", corp.embeddings[42])
    live.delete(17)
    patch = live.commit()
    print(f"\nepoch {live.epoch}: 3 mutations -> {len(patch.cols)} clusters "
          f"touched, patch {kb(patch.wire_bytes)} "
          f"(vs {kb(live.system.cfg.hint_bytes)} full hint)")

    synced = cache.sync(live.epochs)
    print(f"client synced epoch {cache.epoch} for {kb(synced)}")

    top, stats = live.query(corp.embeddings[3], epoch=cache.epoch, top_k=3,
                            key=jax.random.PRNGKey(0))
    print(f"private query near the insert -> ids {[d for d, _, _ in top]}")
    assert any(d == 9001 for d, _, _ in top)
    top, _ = live.query(corp.embeddings[42], epoch=cache.epoch, top_k=3,
                        key=jax.random.PRNGKey(1))
    print("revised doc 42 text:",
          [t for d, _, t in top if d == 42][0].decode())

    # -- a stale client is rejected, syncs, retries -------------------------
    live.replace(100, b"doc 100 v2", corp.embeddings[100])
    live.commit()
    from repro.update import StaleEpochError
    try:
        live.query(corp.embeddings[100], epoch=cache.epoch)
    except StaleEpochError as e:
        print(f"\nstale client rejected ({e}); syncing "
              f"{kb(cache.sync(live.epochs))} and retrying")
    top, _ = live.query(corp.embeddings[100], epoch=cache.epoch, top_k=1,
                        key=jax.random.PRNGKey(2))
    print("retry ->", top[0][2].decode())

    # -- deletes until the planner forces a rebuild -------------------------
    for doc in range(0, 480):
        if doc in live._docs:
            live.delete(doc)
    patch = live.commit()
    st = live.commits[-1]
    print(f"\nepoch {live.epoch}: mass delete -> full rebuild "
          f"(reason: {st.reason}), patch {kb(patch.wire_bytes)}, "
          f"m {live.system.db.m}")
    cache.sync(live.epochs)
    print(f"client re-synced; lifetime downlink {kb(cache.bytes_downloaded)}")
    top, _ = live.query(corp.embeddings[500], epoch=cache.epoch, top_k=1,
                        key=jax.random.PRNGKey(3))
    print("post-rebuild query ->", top[0][0])


if __name__ == "__main__":
    main()
