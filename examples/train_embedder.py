"""End-to-end training driver: a ~100M-param qwen3-style embedder/LM trained
for a few hundred steps with the full production stack — fault-tolerant
trainer, async checkpoints, deterministic seekable data, AdamW + cosine.

    PYTHONPATH=src python examples/train_embedder.py --steps 300

The model is the same transformer module the full-size dry-runs lower; only
the dimensions differ.  Loss on the affine-recurrence task should fall well
below the uniform baseline ln(V)≈6.9 within a few hundred steps.
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.data import lm_data  # noqa: E402
from repro.launch.train import FaultTolerantTrainer  # noqa: E402
from repro.models import nn, transformer as tf  # noqa: E402
from repro.optim import optimizers as opt_lib  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/pirrag_embedder_ckpt")
    args = ap.parse_args()

    # ~100M-class: 12L × d768, GQA 12/4, SwiGLU, qk-norm (qwen3-style)
    cfg = tf.LMConfig(name="embedder-100m", n_layers=12, d_model=768,
                      n_heads=12, n_kv_heads=4, head_dim=64, d_ff=2048,
                      vocab=512, qk_norm=True, rope_theta=1e6,
                      attn_chunk_q=128, attn_chunk_kv=128, ce_chunk=128,
                      remat=False)
    params = tf.init(jax.random.PRNGKey(0), cfg)
    print(f"params: {nn.count_params(params) / 1e6:.1f}M")

    opt = opt_lib.adamw(opt_lib.cosine_schedule(3e-4, 20, args.steps),
                        weight_decay=0.01)

    def step_fn(state, batch):
        (loss, m), grads = jax.value_and_grad(
            lambda p: tf.lm_loss(p, batch, cfg), has_aux=True)(
            state["params"])
        new_p, new_o = opt.update(grads, state["opt"], state["params"])
        return {"params": new_p, "opt": new_o}, {"loss": loss, **m}

    def init_state(key):
        p = tf.init(key, cfg)
        return {"params": p, "opt": opt.init(p)}

    def batch_at(step):
        b = lm_data.batch_at(0, step, batch=args.batch, seq=args.seq,
                             vocab=cfg.vocab, n_offsets=4)
        return {k: jnp.asarray(v) for k, v in b.items()}

    trainer = FaultTolerantTrainer(step_fn, init_state,
                                   ckpt_dir=args.ckpt_dir, ckpt_every=50)
    t0 = time.perf_counter()
    losses = []
    orig = trainer.step_fn

    state, start = trainer._restore_or_init(jax.random.PRNGKey(0))
    for step in range(start, args.steps):
        state, metrics = orig(state, batch_at(step))
        if step % 25 == 0 or step == args.steps - 1:
            l = float(metrics["loss"])
            losses.append(l)
            print(f"step {step:4d}  loss {l:.4f}  "
                  f"({(time.perf_counter() - t0):.0f}s)")
        if (step + 1) % trainer.ckpt_every == 0:
            trainer.saver.save(trainer.ckpt_dir, state, step=step, keep=3)
    trainer.saver.wait()
    import math
    print(f"\nuniform baseline ln(V) = {math.log(cfg.vocab):.2f}; "
          f"final loss = {losses[-1]:.2f}")
    if args.steps >= 100:
        assert losses[-1] < losses[0] - 0.5, "training did not make progress"
        print("OK: loss decreased; checkpoints in", args.ckpt_dir)
    else:
        print("(short run — skip convergence assertion); checkpoints in",
              args.ckpt_dir)


if __name__ == "__main__":
    main()
