"""Quickstart: a fully private RAG retrieval in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a synthetic corpus, runs the PIR-RAG offline phase (cluster → chunk →
hint), then answers one query where the server never learns the query
embedding, the cluster, or the documents returned.
"""
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import pipeline  # noqa: E402
from repro.data import corpus as corpus_lib  # noqa: E402


def main():
    print("== PIR-RAG quickstart ==")
    corp = corpus_lib.make_corpus(0, n_docs=1200, emb_dim=64, n_topics=16)
    system = pipeline.PirRagSystem.build(
        corp.texts, corp.embeddings, n_clusters=16, impl="xla",
        balance_factor=1.3,          # beyond-paper: caps the downlink
    )
    print(f"offline setup: {system.setup_seconds:.2f}s | "
          f"db {system.db.m}×{system.db.n} u8 "
          f"({system.db.m * system.db.n / 2**20:.1f} MiB) | "
          f"padding waste {system.db.pad_fraction:.1%}")
    print(f"one-time hint download: {system.cfg.hint_bytes / 2**20:.1f} MiB")

    # the "user" asks something near document 37's topic
    query = corp.embeddings[37] + 0.05 * np.random.default_rng(1).standard_normal(64)
    top, stats = system.query(query.astype(np.float32), top_k=5,
                              key=jax.random.PRNGKey(42))

    print(f"\nuplink {stats.uplink_bytes} B  |  downlink "
          f"{stats.downlink_bytes / 2**20:.2f} MiB  |  server "
          f"{stats.server_ms:.1f} ms  |  client {stats.client_ms:.1f} ms")
    print("server's view: one pseudorandom uint32 vector — nothing else.\n")
    for doc_id, score, text in top:
        print(f"  doc {doc_id:5d}  cos={score:.3f}  {text[:48]!r}")
    assert any(d == 37 for d, _, _ in top), "expected the anchor doc in top-5"
    print("\nOK: anchor document retrieved privately.")


if __name__ == "__main__":
    main()
