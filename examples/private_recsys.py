"""PIR-RAG × RecSys: private embedding serving for MIND.

    PYTHONPATH=src python examples/private_recsys.py

A recommendation request is KEYED: the client holds sparse feature ids
(its click history + a candidate item) and needs the matching embedding
rows — it does not need similarity search.  `PirRagSystem.build_keyed`
indexes the stacked item table for exactly this access pattern: row ids
map to fixed groups, a 3-way cuckoo placement turns the whole id multiset
into ONE batch of per-bucket PIR queries, and the server answers every
row in a single bucketed pass.  The provider sees only uint32 ciphertext
noise — never which items the user clicked or is being scored on.

The recovered rows are bit-identical to ``params["emb"]["table"][ids]``,
so scattering them into an otherwise-zero table
(`models.embedding.table_from_rows`) lets the UNMODIFIED `recsys.serve`
produce bitwise the same scores as the public-table run — checked below
by comparing the raw float bit patterns.
"""
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import pipeline  # noqa: E402
from repro.models import embedding, recsys  # noqa: E402
from repro.configs.mind import SMOKE  # noqa: E402


def main():
    cfg = SMOKE
    rng = np.random.default_rng(0)
    params = recsys.init(jax.random.PRNGKey(0), cfg)

    # The provider's catalogue = the stacked item embedding table.  The
    # keyed index is built ONCE offline; per-request cost is independent
    # of how many ids the request touches.
    table = np.asarray(params["emb"]["table"], np.float32)
    system = pipeline.PirRagSystem.build_keyed(table, kappa=16, impl="xla",
                                               seed=0)

    # A user's private request: click history + one candidate to score.
    hist = rng.integers(0, cfg.vocab_per_field, (1, cfg.hist_len))
    mask = np.ones((1, cfg.hist_len), bool)
    target = rng.integers(0, cfg.vocab_per_field, (1,))
    batch = {"hist": jnp.asarray(hist), "hist_mask": jnp.asarray(mask),
             "target": jnp.asarray(target)}

    # Every embedding row the request touches, fetched in one keyed batch.
    ids = np.concatenate([hist.ravel(), target]).astype(np.int64)
    rows, stats = system.lookup(ids, key=jax.random.PRNGKey(1))
    assert np.array_equal(rows, table[ids]), "PIR rows must be bit-exact"

    # Private params: fetched rows scattered into a zero table; the model
    # code runs unmodified on them.
    priv = {"emb": embedding.table_from_rows(len(table), cfg.embed_dim,
                                             ids, rows),
            "bilinear": params["bilinear"]}
    score_priv = np.asarray(recsys.serve(priv, batch, cfg))
    score_pub = np.asarray(recsys.serve(params, batch, cfg))
    bitwise = np.array_equal(score_priv.view(np.uint32),
                             score_pub.view(np.uint32))
    assert bitwise, (score_priv, score_pub)

    print("private MIND scoring (provider sees only uint32 noise):")
    print(f"  score[private table] = {float(score_priv[0]):+.6f}")
    print(f"  score[public  table] = {float(score_pub[0]):+.6f}   "
          f"bitwise_equal={bitwise}")
    print(f"\nkeyed lookup: kappa={stats.kappa} ids in {stats.groups} "
          f"groups via {stats.n_buckets} bucket queries ({stats.mode})")
    print(f"uplink {stats.uplink_bytes} B (id-independent), downlink "
          f"{stats.downlink_bytes / 1024:.1f} KiB, server "
          f"{stats.server_ms:.1f} ms")


if __name__ == "__main__":
    main()
