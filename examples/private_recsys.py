"""PIR-RAG × RecSys: private candidate retrieval for MIND.

    PYTHONPATH=src python examples/private_recsys.py

The paper's cluster-and-fetch applies directly to retrieval-stage recsys:
candidate item embeddings are clustered; the user's interest vector picks a
cluster CLIENT-SIDE; one PIR query fetches the entire candidate cluster; the
client re-ranks locally with MIND's max-over-interests score.  The provider
never learns the user's interests or which items were considered.
"""
import sys

sys.path.insert(0, "src")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import pipeline  # noqa: E402
from repro.models import recsys  # noqa: E402
from repro.configs.mind import SMOKE  # noqa: E402


def main():
    cfg = SMOKE
    rng = np.random.default_rng(0)
    params = recsys.init(jax.random.PRNGKey(0), cfg)

    # the candidate catalogue = the item embedding table (vocab items)
    table = np.asarray(params["emb"]["table"], np.float32)
    item_texts = [f"item:{i} meta".encode() for i in range(len(table))]

    system = pipeline.PirRagSystem.build(item_texts, table, n_clusters=8,
                                         impl="xla")

    # a user's private interests from their (private) history
    hist = rng.integers(0, cfg.vocab_per_field, (1, cfg.hist_len))
    mask = np.ones((1, cfg.hist_len), bool)
    interests = np.asarray(recsys.mind_interests(
        params, jax.numpy.asarray(hist), jax.numpy.asarray(mask), cfg))[0]

    # pick the strongest interest, privately fetch its candidate cluster
    main_interest = interests[np.argmax(np.linalg.norm(interests, axis=1))]
    top, stats = system.query(main_interest.astype(np.float32), top_k=5,
                              key=jax.random.PRNGKey(1))

    print("private candidate retrieval (provider sees only uint32 noise):")
    for item_id, score, text in top:
        # client-side final score: max over ALL interests
        s = float(np.max(interests @ table[item_id]))
        print(f"  item {item_id:4d}  cluster-cos={score:.3f} "
              f"mind-score={s:.3f}  {text.decode()}")
    print(f"\nuplink {stats.uplink_bytes} B, downlink "
          f"{stats.downlink_bytes / 1024:.1f} KiB, server "
          f"{stats.server_ms:.1f} ms")


if __name__ == "__main__":
    main()
